//! Figure-regeneration timing: how long each paper experiment takes with
//! this implementation (reduced set counts; the examples run the full
//! versions).  This is the end-to-end harness benchmark of the §Perf
//! pass.

use rtgpu::gen::GenConfig;
use rtgpu::harness::sweep::{run_sweep, SweepSpec};
use rtgpu::harness::throughput::throughput_gain;
use rtgpu::harness::validate::{run_validation, TimeModel};
use rtgpu::util::bench::{bench_n, black_box, header};

fn main() {
    println!("{}", header());

    // Fig 8-style sweep (one ratio, 20 sets/point, 12 points, 3 tests).
    println!("{}", bench_n("fig8_one_ratio_sweep_20sets", 0, 3, || {
        let spec = SweepSpec::quick(GenConfig::default().with_length_ratio(1.0, 2.0), 42);
        black_box(run_sweep(&spec, 0).len());
    }).row());

    // Fig 9/10 variants.
    println!("{}", bench_n("fig9_subtasks7_sweep_20sets", 0, 3, || {
        let spec = SweepSpec::quick(GenConfig::default().with_subtasks(7), 42);
        black_box(run_sweep(&spec, 0).len());
    }).row());
    println!("{}", bench_n("fig10_tasks7_sweep_20sets", 0, 3, || {
        let spec = SweepSpec::quick(GenConfig::default().with_tasks(7), 42);
        black_box(run_sweep(&spec, 0).len());
    }).row());

    // Fig 11 (small platform → bigger search space per set).
    println!("{}", bench_n("fig11_gn5_sweep_20sets", 0, 3, || {
        let mut spec = SweepSpec::quick(GenConfig::default(), 42);
        spec.gn_total = 5;
        black_box(run_sweep(&spec, 0).len());
    }).row());

    // Fig 12/13 validation (analysis + simulation per set).
    let utils: Vec<f64> = (1..=6).map(|i| i as f64 * 0.4).collect();
    println!("{}", bench_n("fig12_validation_10sets", 0, 3, || {
        black_box(run_validation(&GenConfig::default(), &utils, 10, 42, 10, TimeModel::Worst)
            .analysis
            .len());
    }).row());
    println!("{}", bench_n("fig13_validation_10sets", 0, 3, || {
        black_box(run_validation(&GenConfig::default(), &utils, 10, 42, 10, TimeModel::Average)
            .analysis
            .len());
    }).row());

    // Fig 14 throughput gains.
    println!("{}", bench_n("fig14_throughput_10sets", 0, 3, || {
        black_box(throughput_gain(&GenConfig::default(), &utils, 10, 42, 10).len());
    }).row());
}
