//! Analysis hot-path benchmarks + the DESIGN.md §6 ablations:
//! grid vs greedy allocation search, and the Theorem-5.6 bound ablation
//! (R1 / R2 / R3 contributions, acceptance + runtime).

use rtgpu::analysis::e2e::E2eBounds;
use rtgpu::analysis::rtgpu::{evaluate, schedule, RtgpuOpts, Search};
use rtgpu::analysis::workload::SuspView;
use rtgpu::analysis::{analyze, Approach};
use rtgpu::gen::{generate_batch, GenConfig};
use rtgpu::util::bench::{bench, black_box, header};

fn main() {
    println!("{}", header());
    let cfg = GenConfig::default();
    let sets = generate_batch(42, &cfg, 1.0, 50);

    // Workload function (the innermost kernel of every fixed point).
    let view = SuspView::new(vec![2.0, 3.0, 1.5, 2.5, 2.0], vec![4.0, 6.0, 3.0, 5.0], 10.0, 40.0);
    println!("{}", bench("workload_fn_max_t200", || {
        black_box(view.max_workload(black_box(200.0)));
    }).row());

    // Single-allocation evaluation (the unit of the grid search).
    let opts = RtgpuOpts::default();
    println!("{}", bench("rtgpu_evaluate_one_allocation", || {
        black_box(evaluate(&sets[0], &vec![2, 2, 2, 2, 2], &opts));
    }).row());

    // Full schedulability tests.
    for (name, ap) in [
        ("rtgpu_grid_full_test", Approach::Rtgpu),
        ("selfsusp_full_test", Approach::SelfSuspension),
        ("stgm_full_test", Approach::Stgm),
    ] {
        let mut i = 0;
        println!("{}", bench(name, || {
            black_box(analyze(&sets[i % sets.len()], 10, ap, Search::Grid));
            i += 1;
        }).row());
    }

    // --- Ablation: grid vs greedy (runtime + schedulability loss) -----
    let mut i = 0;
    println!("{}", bench("rtgpu_greedy_full_test", || {
        black_box(schedule(&sets[i % sets.len()], 10, &opts, Search::Greedy));
        i += 1;
    }).row());
    let grid_ok = sets.iter().filter(|ts| schedule(ts, 10, &opts, Search::Grid).schedulable).count();
    let greedy_ok =
        sets.iter().filter(|ts| schedule(ts, 10, &opts, Search::Greedy).schedulable).count();
    println!("\nallocation ablation @util 1.0: grid accepts {grid_ok}/50, greedy accepts {greedy_ok}/50");

    // --- Ablation: Theorem 5.6 bounds ---------------------------------
    println!("\nbound ablation @util 1.0 (accepted sets out of 50):");
    for (name, bounds) in [
        ("R1 only  ", E2eBounds { use_r1: true, use_r2: false, use_r3: false }),
        ("R2 only  ", E2eBounds { use_r1: false, use_r2: true, use_r3: false }),
        ("R3 only  ", E2eBounds { use_r1: false, use_r2: false, use_r3: true }),
        ("R1+R2    ", E2eBounds { use_r1: true, use_r2: true, use_r3: false }),
        ("R1+R2+R3 ", E2eBounds::default()),
    ] {
        let o = RtgpuOpts { bounds, ..Default::default() };
        let ok = sets.iter().filter(|ts| schedule(ts, 10, &o, Search::Grid).schedulable).count();
        println!("  {name} accepts {ok}/50");
    }
}
