//! Analysis hot-path benchmarks + the DESIGN.md §6 ablations:
//! grid vs greedy allocation search, the Theorem-5.6 bound ablation
//! (R1 / R2 / R3 contributions, acceptance + runtime), and the
//! cold-vs-warm incremental-admission comparison (emitted to
//! `BENCH_admission.json`).

use rtgpu::analysis::e2e::E2eBounds;
use rtgpu::analysis::rtgpu::{evaluate, schedule, RtgpuOpts, Search};
use rtgpu::analysis::workload::SuspView;
use rtgpu::analysis::{analyze, Approach};
use rtgpu::coordinator::AdmissionState;
use rtgpu::gen::{generate_batch, generate_taskset, GenConfig};
use rtgpu::model::Platform;
use rtgpu::util::bench::{bench, black_box, header};
use rtgpu::util::rng::Pcg;

fn main() {
    println!("{}", header());
    let cfg = GenConfig::default();
    let sets = generate_batch(42, &cfg, 1.0, 50);

    // Workload function (the innermost kernel of every fixed point).
    let view = SuspView::new(vec![2.0, 3.0, 1.5, 2.5, 2.0], vec![4.0, 6.0, 3.0, 5.0], 10.0, 40.0);
    println!("{}", bench("workload_fn_max_t200", || {
        black_box(view.max_workload(black_box(200.0)));
    }).row());

    // Single-allocation evaluation (the unit of the grid search).
    let opts = RtgpuOpts::default();
    println!("{}", bench("rtgpu_evaluate_one_allocation", || {
        black_box(evaluate(&sets[0], &vec![2, 2, 2, 2, 2], &opts));
    }).row());

    // Full schedulability tests.
    for (name, ap) in [
        ("rtgpu_grid_full_test", Approach::Rtgpu),
        ("selfsusp_full_test", Approach::SelfSuspension),
        ("stgm_full_test", Approach::Stgm),
    ] {
        let mut i = 0;
        println!("{}", bench(name, || {
            black_box(analyze(&sets[i % sets.len()], 10, ap, Search::Grid));
            i += 1;
        }).row());
    }

    // --- Ablation: grid vs greedy (runtime + schedulability loss) -----
    let mut i = 0;
    println!("{}", bench("rtgpu_greedy_full_test", || {
        black_box(schedule(&sets[i % sets.len()], 10, &opts, Search::Greedy));
        i += 1;
    }).row());
    let grid_ok =
        sets.iter().filter(|ts| schedule(ts, 10, &opts, Search::Grid).schedulable).count();
    let greedy_ok =
        sets.iter().filter(|ts| schedule(ts, 10, &opts, Search::Greedy).schedulable).count();
    println!(
        "\nallocation ablation @util 1.0: grid accepts {grid_ok}/50, greedy accepts {greedy_ok}/50"
    );

    // --- Ablation: Theorem 5.6 bounds ---------------------------------
    println!("\nbound ablation @util 1.0 (accepted sets out of 50):");
    for (name, bounds) in [
        ("R1 only  ", E2eBounds { use_r1: true, use_r2: false, use_r3: false }),
        ("R2 only  ", E2eBounds { use_r1: false, use_r2: true, use_r3: false }),
        ("R3 only  ", E2eBounds { use_r1: false, use_r2: false, use_r3: true }),
        ("R1+R2    ", E2eBounds { use_r1: true, use_r2: true, use_r3: false }),
        ("R1+R2+R3 ", E2eBounds::default()),
    ] {
        let o = RtgpuOpts { bounds, ..Default::default() };
        let ok = sets.iter().filter(|ts| schedule(ts, 10, &o, Search::Grid).schedulable).count();
        println!("  {name} accepts {ok}/50");
    }

    // --- SharedCache::retain_keys (the warm removal path's cleanup) ----
    // Build a cache with hundreds of live contexts (one per (task, gn)
    // the evaluations visit) and time the retain over all-live keys —
    // the old Vec::contains scan made this O(entries × live).
    {
        use rtgpu::analysis::rtgpu::Evaluator;
        use rtgpu::analysis::SharedCache;
        let big = generate_taskset(&mut Pcg::new(7), &GenConfig::default().with_tasks(24), 2.0);
        let shared = SharedCache::new();
        let eval = Evaluator::with_shared(&big, 8, &opts, &shared);
        for gn in 1..=8 {
            black_box(eval.bounds(&vec![gn; big.len()]));
        }
        let live: Vec<u64> = (0..big.len() as u64).collect();
        let n_ctx = shared.len();
        let r = bench("shared_cache_retain_keys_all_live", || {
            shared.retain_keys(black_box(&live));
        });
        println!("\n{}  [{n_ctx} live contexts]", r.row());
    }

    // --- Incremental admission: cold full grid vs warm add_app --------
    // An 8-app schedulable set; the warm path admits the 8th app into a
    // state that already holds the other 7 (cached contexts + cached
    // feasible allocation), vs rerunning Algorithm 2 from scratch.
    let cfg8 = GenConfig::default().with_tasks(8);
    let mut seed = 4242u64;
    let ts8 = loop {
        let ts = generate_taskset(&mut Pcg::new(seed), &cfg8, 0.6);
        if schedule(&ts, 10, &opts, Search::Grid).schedulable {
            break ts;
        }
        seed += 1;
    };

    println!();
    let cold = bench("admission_cold_full_grid_8apps", || {
        black_box(schedule(&ts8, 10, &opts, Search::Grid));
    });
    println!("{}", cold.row());

    let mut state = AdmissionState::new(Platform::new(10), opts);
    for t in ts8.tasks.iter().take(7) {
        let (_, d) = state.add_app(t.clone());
        assert!(d.schedulable, "7-app warm base must admit");
    }
    let newcomer = ts8.tasks[7].clone();
    let mut fast = 0usize;
    let mut admitted = 0usize;
    let mut iters = 0usize;
    let warm = bench("admission_warm_add_remove_8th_app", || {
        let (key, d) = state.add_app(newcomer.clone());
        fast += usize::from(d.path.is_fast());
        admitted += usize::from(d.schedulable);
        iters += 1;
        black_box(state.remove_app(key));
    });
    println!("{}", warm.row());
    if admitted != iters {
        println!("WARNING: 8th app admitted only {admitted}/{iters} times (expected all)");
    }

    let speedup = cold.summary.mean / warm.summary.mean.max(1e-12);
    let fast_fraction = fast as f64 / iters.max(1) as f64;
    let json = format!(
        "{{\n  \"apps\": 8,\n  \"gn_total\": 10,\n  \"seed\": {seed},\n  \
         \"cold_full_grid_mean_s\": {:.9},\n  \"cold_full_grid_p50_s\": {:.9},\n  \
         \"warm_add_remove_mean_s\": {:.9},\n  \"warm_add_remove_p50_s\": {:.9},\n  \
         \"speedup_mean\": {:.3},\n  \"fast_path_fraction\": {:.3},\n  \
         \"cache_contexts\": {},\n  \"cache_hit_rate\": {:.3}\n}}\n",
        cold.summary.mean,
        cold.summary.p50,
        warm.summary.mean,
        warm.summary.p50,
        speedup,
        fast_fraction,
        state.cache().len(),
        state.cache().hit_rate(),
    );
    std::fs::write("BENCH_admission.json", &json).expect("write BENCH_admission.json");
    println!(
        "\nincremental admission @8 apps: warm add+remove is {speedup:.1}× faster than a cold \
         full grid (fast path {fast}/{iters}); BENCH_admission.json written"
    );
    // Acceptance bar (reported, not asserted — benches should not crash
    // on machine variance): warm must be ≥5× faster than cold.
    let bar = if speedup >= 5.0 { "PASS" } else { "BELOW BAR" };
    println!("acceptance bar (warm ≥5× cold): {bar}");

    // --- reinflate: the drift-storm path ------------------------------
    // Every live app named at once (factor 1.0 keeps the model fixed so
    // iterations don't compound): survivor filtering is a HashSet lookup
    // per live key — the old Vec::contains scan made a full-fleet storm
    // O(live²) — followed by the cache purge and a warm re-decision.
    let factors: Vec<(u64, f64)> = (0..state.len() as u64).map(|k| (k, 1.0)).collect();
    println!("{}", bench("admission_reinflate_all_apps_storm", || {
        black_box(state.reinflate(black_box(&factors)));
    }).row());
}
