//! Cluster-layer benchmarks (DESIGN.md §6/§8): placement time per
//! policy, warm vs cold re-admission on a device drain (the fleet
//! recovery path), and per-device GPU-utilization balance — emitted to
//! `BENCH_cluster.json`.

use std::collections::BTreeMap;

use rtgpu::analysis::RtgpuOpts;
use rtgpu::cluster::{ClusterState, PlacementPolicy};
use rtgpu::gen::{generate_taskset, GenConfig};
use rtgpu::model::{ClusterPlatform, RtTask};
use rtgpu::util::bench::{bench, black_box, header};
use rtgpu::util::json::Json;
use rtgpu::util::rng::Pcg;
use rtgpu::util::stats::Summary;

const DEVICES: usize = 4;
const GN: usize = 10;
const APPS: usize = 8;

fn fresh_state(devices: usize) -> ClusterState {
    ClusterState::new(ClusterPlatform::homogeneous(devices, GN), RtgpuOpts::default())
}

fn main() {
    println!("{}", header());
    let cfg = GenConfig::default().with_tasks(APPS);

    // A seed whose set places fully under both policies AND survives a
    // device-0 drain without rejections (the recovery scenario below).
    let mut seed = 9000u64;
    let ffd = PlacementPolicy::FirstFitDecreasing;
    let tasks: Vec<RtTask> = loop {
        assert!(seed < 9500, "no placeable 8-app seed in 500 tries — generator/admission drifted");
        let ts = generate_taskset(&mut Pcg::new(seed), &cfg, 2.0);
        let ffd_ok = fresh_state(DEVICES).place_all(&ts.tasks, ffd).all_placed();
        let drain_ok = {
            let mut s = fresh_state(DEVICES);
            s.place_all(&ts.tasks, PlacementPolicy::WorstFit).all_placed()
                && s.drain_device(0, PlacementPolicy::WorstFit).rejected == 0
        };
        if ffd_ok && drain_ok {
            break ts.tasks;
        }
        seed += 1;
    };

    // --- placement time per policy -------------------------------------
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("apps".into(), Json::Num(APPS as f64));
    obj.insert("devices".into(), Json::Num(DEVICES as f64));
    obj.insert("gn_per_device".into(), Json::Num(GN as f64));
    obj.insert("seed".into(), Json::Num(seed as f64));
    for policy in PlacementPolicy::ALL {
        let name = format!("placement_{}_{}apps_{}dev", policy.name(), APPS, DEVICES);
        let r = bench(&name, || {
            let mut s = fresh_state(DEVICES);
            black_box(s.place_all(&tasks, policy).all_placed());
        });
        println!("{}", r.row());
        obj.insert(
            format!("placement_{}_mean_s", policy.name().replace('-', "_")),
            Json::Num(r.summary.mean),
        );
    }

    // --- warm vs cold re-admission on device failure --------------------
    // The operational choice after a drain: Warm = incrementally re-admit
    // only the displaced apps onto the three survivors, whose
    // AdmissionStates (and analysis caches) are still live.  Cold = the
    // whole post-failure fleet is re-scheduled from scratch.  The speedup
    // therefore combines BOTH effects of incremental recovery — fewer
    // admissions (k displaced vs all n apps) and warm survivor caches;
    // BENCH_admission.json isolates the pure cache-warmth factor.
    let policy = PlacementPolicy::WorstFit;
    let mut state = fresh_state(DEVICES);
    let report = state.place_all(&tasks, policy);
    assert!(report.all_placed());
    let displaced: Vec<RtTask> = report
        .placed
        .iter()
        .filter(|&&(_, _, dev)| dev == 0)
        .map(|&(idx, _, _)| tasks[idx].clone())
        .collect();
    let outcome = state.drain_device(0, policy);
    assert_eq!(outcome.rejected, 0, "seed search guaranteed a clean drain");
    for &(key, _) in &outcome.replaced {
        assert!(state.remove(key));
    }
    // `state` now holds the survivors only, caches warm from the drain.
    let n_displaced = displaced.len();
    let warm = bench("drain_warm_readmit_displaced", || {
        let mut keys = Vec::with_capacity(n_displaced);
        for t in &displaced {
            if let Some((key, _)) = state.try_place(t, policy) {
                keys.push(key);
            }
        }
        for key in keys {
            state.remove(key);
        }
    });
    println!("{}", warm.row());
    let cold = bench("drain_cold_full_reschedule_survivor_fleet", || {
        let mut s = fresh_state(DEVICES - 1);
        black_box(s.place_all(&tasks, policy).all_placed());
    });
    println!("{}", cold.row());
    let speedup = cold.summary.mean / warm.summary.mean.max(1e-12);
    obj.insert("drain_displaced_apps".into(), Json::Num(n_displaced as f64));
    obj.insert("cold_rescheduled_apps".into(), Json::Num(APPS as f64));
    obj.insert("warm_readmit_mean_s".into(), Json::Num(warm.summary.mean));
    obj.insert("cold_full_reschedule_mean_s".into(), Json::Num(cold.summary.mean));
    obj.insert("warm_speedup".into(), Json::Num((speedup * 1000.0).round() / 1000.0));

    // --- per-device utilization balance ---------------------------------
    println!();
    for policy in PlacementPolicy::ALL {
        let mut s = fresh_state(DEVICES);
        s.place_all(&tasks, policy);
        let utils = s.gpu_utils();
        let sum = Summary::of(&utils).expect("non-empty fleet");
        let spread = sum.max - sum.min;
        println!(
            "balance {}: per-device GPU util {:?} → spread {:.3}, sd {:.3}",
            policy.name(),
            utils.iter().map(|u| (u * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
            spread,
            sum.sd
        );
        let tag = policy.name().replace('-', "_");
        obj.insert(format!("balance_{tag}_spread"), Json::Num((spread * 1e6).round() / 1e6));
        obj.insert(format!("balance_{tag}_sd"), Json::Num((sum.sd * 1e6).round() / 1e6));
    }

    let json = Json::Obj(obj);
    std::fs::write("BENCH_cluster.json", format!("{json}\n")).expect("write BENCH_cluster.json");
    println!(
        "\ndevice-failure recovery: warm incremental re-admission ({n_displaced} displaced apps) \
         is {speedup:.1}× faster than a cold full re-schedule of all {APPS} apps \
         (fewer admissions + warm caches); BENCH_cluster.json written"
    );
    // Reported, not asserted (machine variance): incremental must win.
    let bar = if speedup >= 2.0 { "PASS" } else { "BELOW BAR" };
    println!("acceptance bar (incremental ≥2× full re-schedule): {bar}");
}
