//! Cluster-layer benchmarks (DESIGN.md §6/§8/§11): placement time per
//! policy, warm vs cold re-admission on a device drain (the fleet
//! recovery path), per-device GPU-utilization balance, and the
//! fleet-scale placement race (serial-scan reference vs utilization
//! index vs power-of-two-choices vs parallel probing) — emitted to
//! `BENCH_cluster.json`.
//!
//! `--smoke` shrinks the scaling race to 100 devices × 1k apps for the
//! CI wall-clock budget; the default full run places 10·G apps on
//! G ∈ {64, 256, 1024} devices.  `--scan-all` also runs the quadratic
//! serial-scan reference at G = 1024 (minutes; skipped by default, and
//! the skip is printed so the JSON is never silently incomplete).

use std::collections::BTreeMap;

use rtgpu::analysis::RtgpuOpts;
use rtgpu::cluster::{ClusterState, PlacementPolicy};
use rtgpu::gen::{generate_taskset, GenConfig};
use rtgpu::model::testing::simple_task;
use rtgpu::model::{Bounds, ClusterPlatform, GpuSegment, KernelClass, RtTask};
use rtgpu::util::bench::{bench, bench_n, black_box, header};
use rtgpu::util::json::Json;
use rtgpu::util::rng::Pcg;
use rtgpu::util::stats::Summary;

const DEVICES: usize = 4;
const GN: usize = 10;
const APPS: usize = 8;

fn fresh_state(devices: usize) -> ClusterState {
    ClusterState::new(ClusterPlatform::homogeneous(devices, GN), RtgpuOpts::default())
}

/// A light application for the fleet-scale race: ≈0.035 utilization, one
/// 1-SM-class kernel, id-dependent GPU weight and deadline so placement
/// order and device sorts do real comparisons instead of all-ties.
fn fleet_app(id: usize) -> RtTask {
    let mut t = simple_task(id);
    t.cpu = vec![Bounds::new(0.4, 0.5), Bounds::new(0.4, 0.5)];
    t.mem = vec![Bounds::new(0.2, 0.25), Bounds::new(0.2, 0.25)];
    let gw = 1.5 + (id % 13) as f64 * 0.04;
    t.gpu = vec![GpuSegment::new(
        Bounds::new(gw * 0.8, gw),
        Bounds::new(0.0, 0.9),
        KernelClass::Compute,
    )];
    t.deadline = 80.0 + (id % 7) as f64;
    t.period = 100.0;
    t
}

fn main() {
    println!("{}", header());
    let cfg = GenConfig::default().with_tasks(APPS);

    // A seed whose set places fully under both policies AND survives a
    // device-0 drain without rejections (the recovery scenario below).
    let mut seed = 9000u64;
    let ffd = PlacementPolicy::FirstFitDecreasing;
    let tasks: Vec<RtTask> = loop {
        assert!(seed < 9500, "no placeable 8-app seed in 500 tries — generator/admission drifted");
        let ts = generate_taskset(&mut Pcg::new(seed), &cfg, 2.0);
        let ffd_ok = fresh_state(DEVICES).place_all(&ts.tasks, ffd).all_placed();
        let drain_ok = {
            let mut s = fresh_state(DEVICES);
            s.place_all(&ts.tasks, PlacementPolicy::WorstFit).all_placed()
                && s.drain_device(0, PlacementPolicy::WorstFit).rejected == 0
        };
        if ffd_ok && drain_ok {
            break ts.tasks;
        }
        seed += 1;
    };

    // --- placement time per policy -------------------------------------
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("apps".into(), Json::Num(APPS as f64));
    obj.insert("devices".into(), Json::Num(DEVICES as f64));
    obj.insert("gn_per_device".into(), Json::Num(GN as f64));
    obj.insert("seed".into(), Json::Num(seed as f64));
    for policy in PlacementPolicy::ALL {
        let name = format!("placement_{}_{}apps_{}dev", policy.name(), APPS, DEVICES);
        let r = bench(&name, || {
            let mut s = fresh_state(DEVICES);
            black_box(s.place_all(&tasks, policy).all_placed());
        });
        println!("{}", r.row());
        obj.insert(
            format!("placement_{}_mean_s", policy.name().replace('-', "_")),
            Json::Num(r.summary.mean),
        );
    }

    // --- warm vs cold re-admission on device failure --------------------
    // The operational choice after a drain: Warm = incrementally re-admit
    // only the displaced apps onto the three survivors, whose
    // AdmissionStates (and analysis caches) are still live.  Cold = the
    // whole post-failure fleet is re-scheduled from scratch.  The speedup
    // therefore combines BOTH effects of incremental recovery — fewer
    // admissions (k displaced vs all n apps) and warm survivor caches;
    // BENCH_admission.json isolates the pure cache-warmth factor.
    let policy = PlacementPolicy::WorstFit;
    let mut state = fresh_state(DEVICES);
    let report = state.place_all(&tasks, policy);
    assert!(report.all_placed());
    let displaced: Vec<RtTask> = report
        .placed
        .iter()
        .filter(|&&(_, _, dev)| dev == 0)
        .map(|&(idx, _, _)| tasks[idx].clone())
        .collect();
    let outcome = state.drain_device(0, policy);
    assert_eq!(outcome.rejected, 0, "seed search guaranteed a clean drain");
    for &(key, _) in &outcome.replaced {
        assert!(state.remove(key));
    }
    // `state` now holds the survivors only, caches warm from the drain.
    let n_displaced = displaced.len();
    let warm = bench("drain_warm_readmit_displaced", || {
        let mut keys = Vec::with_capacity(n_displaced);
        for t in &displaced {
            if let Some((key, _)) = state.try_place(t, policy) {
                keys.push(key);
            }
        }
        for key in keys {
            state.remove(key);
        }
    });
    println!("{}", warm.row());
    let cold = bench("drain_cold_full_reschedule_survivor_fleet", || {
        let mut s = fresh_state(DEVICES - 1);
        black_box(s.place_all(&tasks, policy).all_placed());
    });
    println!("{}", cold.row());
    let speedup = cold.summary.mean / warm.summary.mean.max(1e-12);
    obj.insert("drain_displaced_apps".into(), Json::Num(n_displaced as f64));
    obj.insert("cold_rescheduled_apps".into(), Json::Num(APPS as f64));
    obj.insert("warm_readmit_mean_s".into(), Json::Num(warm.summary.mean));
    obj.insert("cold_full_reschedule_mean_s".into(), Json::Num(cold.summary.mean));
    obj.insert("warm_speedup".into(), Json::Num((speedup * 1000.0).round() / 1000.0));

    // --- per-device utilization balance ---------------------------------
    println!();
    for policy in PlacementPolicy::ALL {
        let mut s = fresh_state(DEVICES);
        s.place_all(&tasks, policy);
        let utils = s.gpu_utils();
        let sum = Summary::of(utils).expect("non-empty fleet");
        let spread = sum.max - sum.min;
        println!(
            "balance {}: per-device GPU util {:?} → spread {:.3}, sd {:.3}",
            policy.name(),
            utils.iter().map(|u| (u * 1000.0).round() / 1000.0).collect::<Vec<_>>(),
            spread,
            sum.sd
        );
        let tag = policy.name().replace('-', "_");
        obj.insert(format!("balance_{tag}_spread"), Json::Num((spread * 1e6).round() / 1e6));
        obj.insert(format!("balance_{tag}_sd"), Json::Num((sum.sd * 1e6).round() / 1e6));
    }

    // --- fleet-scale placement race (DESIGN.md §11) ---------------------
    // Synthetic light apps (≈0.035 utilization each, distinct-ish GPU
    // weights so the sorts do real work), 10 apps per device on 12-SM
    // devices — enough headroom that admission itself stays cheap and
    // the race isolates candidate selection: the quadratic serial-scan
    // reference vs the maintained utilization index vs sampled p2c vs
    // index + parallel probing (same placements, bit-identical).
    println!();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let scan_all = std::env::args().any(|a| a == "--scan-all");
    let sizes: &[usize] = if smoke { &[100] } else { &[64, 256, 1024] };
    obj.insert("scale_mode".into(), Json::Str(if smoke { "smoke" } else { "full" }.into()));
    let wf = PlacementPolicy::WorstFit;
    for &g in sizes {
        let n_apps = 10 * g;
        let apps: Vec<RtTask> = (0..n_apps).map(fleet_app).collect();
        let plat = ClusterPlatform::homogeneous(g, 12);
        let mk = || ClusterState::new(plat, RtgpuOpts::default());
        let iters = if n_apps >= 10_000 { 1 } else { 2 };

        // The scan reference costs O(G·A²) total — minutes at G = 1024.
        let run_scan = scan_all || g <= 256;
        let scan_mean = if run_scan {
            let r = bench_n(&format!("scale_g{g}_{n_apps}apps_scan_serial"), 0, 1, || {
                let mut s = mk();
                black_box(s.place_all_scan(&apps, wf).placed.len());
            });
            println!("{}", r.row());
            obj.insert(format!("scale_g{g}_scan_serial_s"), Json::Num(r.summary.mean));
            Some(r.summary.mean)
        } else {
            println!(
                "scale_g{g}: serial-scan reference SKIPPED (quadratic; pass --scan-all to run) \
                 — speedups below use the largest scanned fleet"
            );
            None
        };
        let indexed = bench_n(&format!("scale_g{g}_{n_apps}apps_indexed"), 0, iters, || {
            let mut s = mk();
            black_box(s.place_all(&apps, wf).placed.len());
        });
        println!("{}", indexed.row());
        obj.insert(format!("scale_g{g}_indexed_s"), Json::Num(indexed.summary.mean));
        let p2c = bench_n(&format!("scale_g{g}_{n_apps}apps_p2c2"), 0, iters, || {
            let mut s = mk();
            black_box(s.place_all(&apps, PlacementPolicy::P2C).placed.len());
        });
        println!("{}", p2c.row());
        obj.insert(format!("scale_g{g}_p2c2_s"), Json::Num(p2c.summary.mean));
        let par = bench_n(&format!("scale_g{g}_{n_apps}apps_indexed_parallel"), 0, iters, || {
            let mut s = mk().with_parallel(0);
            black_box(s.place_all(&apps, wf).placed.len());
        });
        println!("{}", par.row());
        obj.insert(format!("scale_g{g}_indexed_parallel_s"), Json::Num(par.summary.mean));

        // Acceptance bookkeeping: how many of the 10·G apps actually
        // placed (identical across scan/indexed/parallel by parity;
        // p2c may place fewer — that is its trade).
        let mut s = mk();
        let placed = s.place_all(&apps, wf).placed.len();
        let mut sp = mk();
        let placed_p2c = sp.place_all(&apps, PlacementPolicy::P2C).placed.len();
        obj.insert(format!("scale_g{g}_apps"), Json::Num(n_apps as f64));
        obj.insert(format!("scale_g{g}_placed"), Json::Num(placed as f64));
        obj.insert(format!("scale_g{g}_p2c2_placed"), Json::Num(placed_p2c as f64));
        if let Some(scan) = scan_mean {
            let su_idx = scan / indexed.summary.mean.max(1e-12);
            let su_par = scan / par.summary.mean.max(1e-12);
            let su_p2c = scan / p2c.summary.mean.max(1e-12);
            obj.insert(
                format!("scale_g{g}_indexed_speedup_vs_scan"),
                Json::Num((su_idx * 100.0).round() / 100.0),
            );
            obj.insert(
                format!("scale_g{g}_parallel_speedup_vs_scan"),
                Json::Num((su_par * 100.0).round() / 100.0),
            );
            obj.insert(
                format!("scale_g{g}_p2c2_speedup_vs_scan"),
                Json::Num((su_p2c * 100.0).round() / 100.0),
            );
            println!(
                "scale_g{g}: indexed {su_idx:.1}×, indexed+parallel {su_par:.1}×, \
                 p2c:2 {su_p2c:.1}× vs serial scan ({placed}/{n_apps} placed, \
                 p2c {placed_p2c}/{n_apps})"
            );
        }
    }
    obj.insert("status".into(), Json::Str("measured".into()));

    let json = Json::Obj(obj);
    std::fs::write("BENCH_cluster.json", format!("{json}\n")).expect("write BENCH_cluster.json");
    println!(
        "\ndevice-failure recovery: warm incremental re-admission ({n_displaced} displaced apps) \
         is {speedup:.1}× faster than a cold full re-schedule of all {APPS} apps \
         (fewer admissions + warm caches); BENCH_cluster.json written"
    );
    // Reported, not asserted (machine variance): incremental must win.
    let bar = if speedup >= 2.0 { "PASS" } else { "BELOW BAR" };
    println!("acceptance bar (incremental ≥2× full re-schedule): {bar}");
}
