//! Serving front-end benchmarks (DESIGN.md §14): the single-lock
//! per-request router raced against the sharded, batched
//! [`AdmissionFront`] on sustained arrival streams — shards ∈ {1, 2, 8}
//! × devices ∈ {4, 64} — reporting sustained decisions/sec, admits, and
//! p50/p95/p99 per-decision latency from the front's `LogHistogram`s,
//! plus a submit-side contention family (producers × shards).  Emitted
//! to `BENCH_serve.json`.
//!
//! Parity is asserted, not sampled: for every configuration the
//! batched front must admit and reject exactly as many apps as the
//! serial reference fed the same stream (the decision *sequence* is
//! pinned by `tests/front_parity.rs`; here we keep the race honest).
//!
//! `--smoke` shrinks the stream to 5 apps per device for the CI
//! wall-clock budget; the default run is 20 per device.

use std::collections::BTreeMap;
use std::time::Instant;

use rtgpu::analysis::RtgpuOpts;
use rtgpu::cluster::{ClusterState, PlacementPolicy};
use rtgpu::coordinator::AdmissionFront;
use rtgpu::model::testing::simple_task;
use rtgpu::model::{Bounds, ClusterPlatform, GpuSegment, KernelClass, RtTask};
use rtgpu::telemetry::LogHistogram;
use rtgpu::util::json::Json;

const POLICY: PlacementPolicy = PlacementPolicy::WorstFit;

fn fresh_state(devices: usize) -> ClusterState {
    ClusterState::new(ClusterPlatform::homogeneous(devices, 12), RtgpuOpts::default())
}

/// A light application (≈0.035 utilization): the stream oversubscribes
/// the fleet partway through, so the race covers both the admit-heavy
/// head and the rejection-heavy tail where the batched candidate reuse
/// pays.
fn fleet_app(id: usize) -> RtTask {
    let mut t = simple_task(id);
    t.cpu = vec![Bounds::new(0.4, 0.5), Bounds::new(0.4, 0.5)];
    t.mem = vec![Bounds::new(0.2, 0.25), Bounds::new(0.2, 0.25)];
    let gw = 1.5 + (id % 13) as f64 * 0.04;
    t.gpu = vec![GpuSegment::new(
        Bounds::new(gw * 0.8, gw),
        Bounds::new(0.0, 0.9),
        KernelClass::Compute,
    )];
    t.deadline = 80.0 + (id % 7) as f64;
    t.period = 100.0;
    t
}

struct RunResult {
    admitted: u64,
    rejected: u64,
    decisions_per_s: f64,
    latency: LogHistogram,
}

/// The pre-§14 path: every request takes the router lock and decides
/// alone — here without the lock (single thread), which only flatters
/// the baseline.
fn run_single_lock(apps: &[RtTask], devices: usize) -> RunResult {
    let mut state = fresh_state(devices);
    let mut latency = LogHistogram::new();
    let (mut admitted, mut rejected) = (0u64, 0u64);
    let t0 = Instant::now();
    for t in apps {
        let d0 = Instant::now();
        let placed = state.try_place(t, POLICY).is_some();
        latency.record(d0.elapsed().as_secs_f64() * 1e3);
        if placed {
            admitted += 1;
        } else {
            rejected += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    RunResult { admitted, rejected, decisions_per_s: apps.len() as f64 / wall, latency }
}

/// The sharded front under a sustained stream: one producer submits in
/// order (keeping the decision sequence comparable to the serial
/// reference) while this thread drains batches until everything is
/// decided.
fn run_front(apps: &[RtTask], devices: usize, shards: usize) -> RunResult {
    let front = AdmissionFront::new(shards, POLICY, None);
    let mut state = fresh_state(devices);
    let total = apps.len();
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let producer = &front;
        scope.spawn(move || {
            for t in apps {
                producer.submit(t.clone(), 0);
            }
        });
        let mut decided = 0usize;
        while decided < total {
            decided += front.drain(&mut state).len();
            if decided < total {
                std::hint::spin_loop();
            }
        }
    });
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let m = front.metrics();
    RunResult {
        admitted: m.admitted,
        rejected: m.rejected,
        decisions_per_s: total as f64 / wall,
        latency: m.merged(),
    }
}

fn q(h: &LogHistogram, p: f64) -> f64 {
    h.quantile(p).unwrap_or(0.0)
}

fn row(label: &str, r: &RunResult) {
    println!(
        "{label:<44} {:>10.0} dec/s  admit {:>5}  reject {:>5}  \
         p50 {:>7.4} ms  p95 {:>7.4} ms  p99 {:>7.4} ms",
        r.decisions_per_s,
        r.admitted,
        r.rejected,
        q(&r.latency, 0.50),
        q(&r.latency, 0.95),
        q(&r.latency, 0.99),
    );
}

fn insert(obj: &mut BTreeMap<String, Json>, prefix: &str, r: &RunResult) {
    obj.insert(format!("{prefix}_decisions_per_s"), Json::Num(r.decisions_per_s.round()));
    obj.insert(format!("{prefix}_admitted"), Json::Num(r.admitted as f64));
    obj.insert(format!("{prefix}_rejected"), Json::Num(r.rejected as f64));
    obj.insert(format!("{prefix}_p50_ms"), Json::Num(q(&r.latency, 0.50)));
    obj.insert(format!("{prefix}_p95_ms"), Json::Num(q(&r.latency, 0.95)));
    obj.insert(format!("{prefix}_p99_ms"), Json::Num(q(&r.latency, 0.99)));
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let per_device = if smoke { 5 } else { 20 };
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("scale_mode".into(), Json::Str(if smoke { "smoke" } else { "full" }.into()));
    obj.insert("apps_per_device".into(), Json::Num(per_device as f64));
    obj.insert("policy".into(), Json::Str(POLICY.name().into()));

    // --- sustained decision race: single lock vs sharded front ----------
    let mut parity_ok = true;
    for &devices in &[4usize, 64] {
        let apps: Vec<RtTask> = (0..per_device * devices).map(fleet_app).collect();
        println!("--- {} apps on {} devices ({})", apps.len(), devices, POLICY.name());
        let base = run_single_lock(&apps, devices);
        row(&format!("g{devices}_single_lock"), &base);
        insert(&mut obj, &format!("g{devices}_single_lock"), &base);
        for &shards in &[1usize, 2, 8] {
            let front = run_front(&apps, devices, shards);
            row(&format!("g{devices}_front_shards{shards}"), &front);
            insert(&mut obj, &format!("g{devices}_front_shards{shards}"), &front);
            if (front.admitted, front.rejected) != (base.admitted, base.rejected) {
                parity_ok = false;
                println!(
                    "PARITY VIOLATION g{devices} shards{shards}: \
                     {}/{} vs serial {}/{}",
                    front.admitted, front.rejected, base.admitted, base.rejected
                );
            }
        }
        println!();
    }

    // --- submit-side contention: producers × shards ---------------------
    // Time only the intake (no drain): P producers pushing one chunk
    // each shows the shard split removing the single-queue hot spot.
    let apps: Vec<RtTask> = (0..8 * 1024).map(fleet_app).collect();
    for &shards in &[1usize, 8] {
        for &producers in &[1usize, 4, 8] {
            let front = AdmissionFront::new(shards, POLICY, None);
            let t0 = Instant::now();
            std::thread::scope(|scope| {
                for chunk in apps.chunks(apps.len().div_ceil(producers)) {
                    let front = &front;
                    scope.spawn(move || {
                        for t in chunk {
                            front.submit(t.clone(), 0);
                        }
                    });
                }
            });
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            let rate = apps.len() as f64 / wall;
            println!(
                "submit_contention shards{shards} producers{producers}: {rate:>12.0} submits/s"
            );
            obj.insert(
                format!("submit_shards{shards}_producers{producers}_per_s"),
                Json::Num(rate.round()),
            );
        }
    }

    obj.insert("status".into(), Json::Str("measured".into()));
    obj.insert("parity".into(), Json::Str(if parity_ok { "ok" } else { "VIOLATED" }.into()));
    let json = Json::Obj(obj);
    std::fs::write("BENCH_serve.json", format!("{json}\n")).expect("write BENCH_serve.json");
    println!("\nBENCH_serve.json written");
    println!(
        "acceptance bar (batched front admits/rejects exactly as the serial router): {}",
        if parity_ok { "PASS" } else { "FAIL" }
    );
    assert!(parity_ok, "batched front diverged from the serial router");
}
