//! PJRT runtime hot path: artifact execution latency per kernel class and
//! input handling overhead — the L3 serving-path numbers behind the
//! DESIGN.md §6 perf table.

use rtgpu::runtime::{artifact_dir, Engine};
use rtgpu::util::bench::{bench_n, black_box, header};

fn main() {
    let engine = match Engine::load_dir_filtered(&artifact_dir(), |m| m.name.ends_with("_small")) {
        Ok(e) => e,
        Err(err) => {
            eprintln!("skipping runtime bench (run `make artifacts` first): {err:#}");
            return;
        }
    };
    println!("platform: {}", engine.platform_name());
    println!("{}", header());

    for kind in ["compute", "branch", "memory", "special", "comprehensive"] {
        let name = format!("synthetic_{kind}_small");
        let n = engine.meta(&name).unwrap().inputs[1].element_count();
        let x: Vec<f32> = (0..n).map(|i| i as f32 * 0.001).collect();
        engine.execute_pinned(&name, (0, 7), &[&x]).unwrap();
        println!("{}", bench_n(&format!("exec_{kind}_full_device"), 3, 50, || {
            black_box(engine.execute_pinned(&name, (0, 7), &[&x]).unwrap().values.len());
        }).row());
    }

    // Pinned-range width sensitivity (should be flat on CPU PJRT —
    // pinning is functional, not temporal, on this backend).
    let name = "synthetic_compute_small";
    let n = engine.meta(name).unwrap().inputs[1].element_count();
    let x: Vec<f32> = (0..n).map(|i| i as f32 * 0.001).collect();
    for range in [(0, 1), (0, 3), (0, 7)] {
        println!("{}", bench_n(&format!("exec_compute_vsm{}-{}", range.0, range.1), 3, 50, || {
            black_box(engine.execute_pinned(name, range, &[&x]).unwrap().values.len());
        }).row());
    }

    // Inference artifact (the serving hot path).
    let n = engine.meta("inference_small").unwrap().inputs[1].element_count();
    let x: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
    engine.execute_pinned("inference_small", (0, 7), &[&x]).unwrap();
    println!("{}", bench_n("exec_inference_small", 3, 100, || {
        black_box(engine.execute_pinned("inference_small", (0, 7), &[&x]).unwrap().values.len());
    }).row());
}
